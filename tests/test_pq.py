"""The product-quantized vector path (IndexSpec.dtype="pq").

Five contracts, extending the paper's storage-bound operating point past
uint8 (1 byte/dim) to M bytes/ROW:

  * quantizer: k-means codebook fit is deterministic under a pinned seed;
    reconstruction error shrinks monotonically as M grows; ADC == squared
    L2 to the reconstruction (which is why stage-2 reranks over TRUE
    float32 rows — re-scoring decoded PQ rows would recover nothing).
  * kernels: the Pallas LUT-gather ADC / fused top-k kernels equal the
    numpy references BITWISE (one gather + one add per subspace, in
    subspace order — the PQ extension of the mul+sum reduction-order
    rule).
  * engines: PQ `csd` == PQ `partitioned` == PQ cluster bit-identically
    (ids, dists, hops, dist_calcs) at every fused_hops, with and without
    rerank; stage-1 distances are ADC, stage-2 re-scores true rows.
  * manifest: codebooks ride format_version 3; save/load round-trips to
    bit-identical answers; the mutable (v2) loader refuses v3 with a
    pointer.
  * storage: code rows are pq_m bytes — 16x below uint8 at the paper's
    d=128 (and at the zoo's d=64, where uint8 rows lane-pad to 128 B) —
    and measured cold-cache `bytes_read` drops accordingly.
"""

import contextlib
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.api import SearchRequest
from repro.optim.compression import PQQuantizer, VectorQuantizer

K, EF = 10, 40


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------


def test_fit_deterministic_under_pinned_seed(backend_zoo):
    vecs = backend_zoo.data["vectors"]
    a = PQQuantizer.fit(vecs, 8, seed=0)
    b = PQQuantizer.fit(vecs, 8, seed=0)
    np.testing.assert_array_equal(a.codebooks, b.codebooks)
    assert a.codebooks.dtype == np.float32 and a.codebooks.shape == (8, 256, 8)
    c = PQQuantizer.fit(vecs, 8, seed=1)
    assert not np.array_equal(a.codebooks, c.codebooks), (
        "different seeds must explore different centroid inits")


def test_fit_rejects_non_divisor_m():
    x = np.zeros((32, 64), np.float32)
    with pytest.raises(ValueError, match="divisor"):
        PQQuantizer.fit(x, 7)
    with pytest.raises(ValueError, match="divisor"):
        PQQuantizer.fit(x, 0)


def test_roundtrip_error_monotone_in_m_and_vs_scalar(backend_zoo):
    """More code bytes -> strictly better reconstruction on the pinned
    dataset; and at the operating point (M=8, 8 bytes/row) PQ is far
    lossier than the scalar uint8 quantizer (64 bytes/row here) — the
    measured gap is what justifies reranking over TRUE float32 rows
    instead of decoded codes."""
    vecs = backend_zoo.data["vectors"]
    mse = {}
    for m in (4, 8, 16):
        q = PQQuantizer.fit(vecs, m, seed=0)
        mse[m] = float(np.mean((vecs - q.decode(q.encode(vecs))) ** 2))
    assert mse[4] > mse[8] > mse[16] > 0.0, f"not monotone: {mse}"
    sq = VectorQuantizer.fit(vecs, "uint8")
    mse_scalar = float(np.mean((vecs - sq.decode(sq.encode(vecs))) ** 2))
    assert mse[8] > 10 * mse_scalar, (
        f"PQ@M=8 ({mse[8]:.3g}) should be much lossier than scalar uint8 "
        f"({mse_scalar:.3g}); if not, the true-row rerank rationale is off")


def test_adc_is_distance_to_reconstruction(backend_zoo):
    """The ADC identity: LUT-gather-sum == ||q - decode(codes)||^2."""
    import jax.numpy as jnp

    from repro.optim.compression import build_pq_lut

    vecs = backend_zoo.data["vectors"][:256]
    q = backend_zoo.queries()[:4]
    quant = PQQuantizer.fit(vecs, 8, seed=0)
    codes = quant.encode(vecs)
    lut = np.asarray(build_pq_lut(jnp.asarray(q),
                                  jnp.asarray(quant.codebooks)))
    b_ix = np.arange(len(q))[:, None, None]
    m_ix = np.arange(quant.m)[None, None, :]
    adc = lut[b_ix, m_ix, codes[None].astype(np.int64)].sum(-1)
    rec = quant.decode(codes)
    direct = ((q[:, None] - rec[None]) ** 2).sum(-1)
    np.testing.assert_allclose(adc, direct, rtol=1e-5)


def test_codebooks_json_roundtrip_bitwise():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((300, 32)).astype(np.float32)
    quant = PQQuantizer.fit(x, 4, seed=3)
    back = PQQuantizer.from_json(json.loads(json.dumps(quant.to_json())))
    np.testing.assert_array_equal(back.codebooks, quant.codebooks)
    assert (back.m, back.dsub) == (quant.m, quant.dsub)


# ---------------------------------------------------------------------------
# Pallas LUT kernels vs numpy references
# ---------------------------------------------------------------------------


def _random_luts_codes(bq, bx, m, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    luts = jnp.asarray(rng.uniform(0, 50, size=(bq, m, 256))
                       .astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 256, size=(bx, m)).astype(np.uint8))
    return luts, codes


@pytest.mark.parametrize("bq,bx,m", [(3, 100, 8), (9, 600, 4), (1, 1024, 16)])
def test_pq_adc_matches_ref_bitwise(bq, bx, m):
    from repro.kernels import ops
    from repro.kernels.ref import pq_adc_ref

    luts, codes = _random_luts_codes(bq, bx, m, seed=11)
    got = ops.pq_adc(luts, codes)
    want = pq_adc_ref(luts, codes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pq_topk_matches_ref_bitwise():
    from repro.kernels import ops
    from repro.kernels.ref import pq_topk_ref

    luts, codes = _random_luts_codes(5, 1500, 8, seed=12)
    gv, gi = ops.pq_topk(luts, codes, k=K)
    wv, wi = pq_topk_ref(luts, codes, k=K)
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    # continuous f32 ADC sums tie with negligible probability -> ids too
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_pq_topk_padding_rows_excluded():
    import jax.numpy as jnp

    from repro.kernels import ops

    luts, codes = _random_luts_codes(4, 700, 8, seed=13)
    xpad = jnp.zeros(700, jnp.float32).at[100:].set(jnp.inf)
    _, gi = ops.pq_topk(luts, codes, xpad, k=K)
    assert np.asarray(gi).max() < 100


# ---------------------------------------------------------------------------
# engines: PQ csd == PQ partitioned == PQ cluster, bit for bit
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _fused(svc, h):
    be = svc.backend
    old = be.spec
    be.spec = dataclasses.replace(old, fused_hops=h)
    try:
        yield svc
    finally:
        be.spec = old


def _respond(svc, q, rerank):
    r = svc.search(SearchRequest(queries=q, k=K, ef=EF, rerank=rerank,
                                 with_stats=True))
    return (np.asarray(r.ids), np.asarray(r.dists),
            np.asarray(r.stats.hops), np.asarray(r.stats.dist_calcs))


@pytest.mark.parametrize("fused_hops", [1, 2, 4])
@pytest.mark.parametrize("rerank", [False, True])
def test_pq_csd_bit_identical_to_partitioned(rerank, fused_hops,
                                             backend_zoo):
    """Acceptance: the PQ csd engine answers from M-byte code rows on
    "flash" yet matches the in-memory partitioned engine bit for bit —
    ids, dists, hops, AND dist_calcs — at every fused_hops, because both
    gather from the same `build_pq_lut` tables and accumulate in subspace
    order."""
    sp = backend_zoo.service("pq", "l2")
    sc = backend_zoo.service("pq_csd", "l2")
    q = backend_zoo.queries()
    with _fused(sp, fused_hops):
        want = _respond(sp, q, rerank)
    with _fused(sc, fused_hops):
        got = _respond(sc, q, rerank)
    for g, w, what in zip(got, want, ("ids", "dists", "hops", "dist_calcs")):
        np.testing.assert_array_equal(g, w, err_msg=(
            f"pq csd vs partitioned diverges on {what} "
            f"(fused_hops={fused_hops}, rerank={rerank})"))


@pytest.fixture(scope="module")
def pq_cluster(backend_zoo, tmp_path_factory):
    """A 2-shard PQ cluster over the zoo vectors: codebooks are fit once
    over the union by build_cluster and ride the spec into every shard, so
    it answers in the same code space as the zoo's 2-partition index."""
    from repro.api import IndexSpec
    from repro.cluster.rebalance import build_cluster
    from conftest import ZOO_CFG

    spec = IndexSpec(backend="partitioned", dtype="pq", pq_m=8,
                     num_partitions=1, hnsw=ZOO_CFG, keep_vectors=True)
    router = build_cluster(backend_zoo.data["vectors"], spec, n_shards=2)
    yield router
    router.close()


@pytest.mark.parametrize("rerank", [False, True])
def test_pq_cluster_bit_identical_to_single_index(rerank, pq_cluster,
                                                  backend_zoo):
    """2 shards x 1 partition == 1 index x 2 partitions, bit for bit: the
    union-fit codebooks and the deterministic fit extend the cluster's
    scatter-gather parity contract to PQ."""
    svc = backend_zoo.service("pq", "l2")
    q = backend_zoo.queries()
    rr = pq_cluster.search(SearchRequest(queries=q, k=K, ef=EF,
                                         rerank=rerank))
    rs = svc.search(SearchRequest(queries=q, k=K, ef=EF, rerank=rerank))
    np.testing.assert_array_equal(np.asarray(rr.ids), np.asarray(rs.ids))
    np.testing.assert_array_equal(np.asarray(rr.dists, np.float32),
                                  np.asarray(rs.dists, np.float32))


def test_pq_cluster_router_requires_union_codebooks(backend_zoo):
    """A PQ spec without pre-fitted codebooks must be refused at the
    router (per-shard fits would give incompatible code spaces)."""
    from repro.api import IndexSpec
    from repro.cluster.router import ClusterRouter
    from conftest import ZOO_CFG

    spec = IndexSpec(backend="partitioned", dtype="pq", pq_m=8,
                     num_partitions=1, hnsw=ZOO_CFG)
    with pytest.raises(ValueError, match="build_cluster"):
        ClusterRouter(spec, [])


def test_pq_stage1_dists_are_adc(backend_zoo):
    """Non-rerank distances == ADC (distance to the reconstruction)."""
    svc = backend_zoo.service("pq", "l2")
    quant = svc.quantizer
    resp = svc.search(SearchRequest(queries=backend_zoo.queries(), k=K,
                                    ef=EF))
    ids = np.asarray(resp.ids)
    rec = quant.decode(quant.encode(backend_zoo.data["vectors"]))
    q = backend_zoo.queries()
    want = np.einsum("bkd,bkd->bk", rec[ids] - q[:, None],
                     rec[ids] - q[:, None])
    np.testing.assert_allclose(np.asarray(resp.dists), want, rtol=1e-3,
                               atol=0.1)


def test_pq_rerank_rescoresover_true_float32_rows(backend_zoo):
    """Stage 2 re-scores the candidate pool against the ORIGINAL float32
    rows (not decoded codes): reranked distances equal a numpy recompute
    over the raw vectors, for the in-memory and the csd engine alike."""
    q = backend_zoo.queries()
    vecs = backend_zoo.data["vectors"]
    for backend in ("pq", "pq_csd"):
        svc = backend_zoo.service(backend, "l2")
        resp = svc.search(SearchRequest(queries=q, k=K, ef=EF, rerank=True))
        ids = np.asarray(resp.ids)
        want = np.einsum("bkd,bkd->bk", vecs[ids] - q[:, None],
                         vecs[ids] - q[:, None])
        # the engine evaluates the dot-product form (xsq - 2 x.q + qsq);
        # the direct-difference recompute differs by f32 cancellation noise
        # that scales with the squared norms, not the distance
        np.testing.assert_allclose(np.asarray(resp.dists), want, rtol=1e-2,
                                   atol=1.0, err_msg=backend)


def test_pq_rejects_non_l2_metrics(backend_zoo):
    from repro.api import IndexSpec, SearchService

    with pytest.raises(ValueError, match="l2"):
        SearchService.build(backend_zoo.data["vectors"],
                            IndexSpec(metric="cosine", dtype="pq", pq_m=8,
                                      backend="partitioned"))


# ---------------------------------------------------------------------------
# manifest: codebooks ride format_version 3
# ---------------------------------------------------------------------------


def test_pq_manifest_v3_roundtrip(backend_zoo, tmp_path):
    from repro.api import SearchService
    from repro.api.service import MANIFEST_NAME
    from repro.api.types import PQ_FORMAT_VERSION

    svc = backend_zoo.service("pq", "l2")
    path = str(tmp_path / "pq-index")
    svc.save(path)
    with open(os.path.join(path, MANIFEST_NAME)) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == PQ_FORMAT_VERSION == 3
    spec_json = manifest["spec"]
    assert spec_json["dtype"] == "pq" and spec_json["pq_m"] == 8
    # codebooks survive JSON: float32 -> repr -> float32 is exact
    cb = np.asarray(spec_json["pq_codebooks"], np.float32)
    np.testing.assert_array_equal(cb, svc.quantizer.codebooks)

    svc2 = SearchService.load(path)
    q = backend_zoo.queries()
    r1 = svc.search(SearchRequest(queries=q, k=K, ef=EF))
    r2 = svc2.search(SearchRequest(queries=q, k=K, ef=EF))
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists),
                                  np.asarray(r2.dists))


def test_pq_manifest_refused_by_mutable_loader(backend_zoo, tmp_path):
    """The v2 (mutable) loader must refuse a v3 index and point at the
    right entry point instead of misreading it."""
    from repro.api import MutableSearchService

    svc = backend_zoo.service("pq", "l2")
    path = str(tmp_path / "pq-index-v3")
    svc.save(path)
    with pytest.raises(ValueError, match="format_version=3"):
        MutableSearchService.load(path)


# ---------------------------------------------------------------------------
# storage: M bytes/row (16x under uint8), fewer bytes over the "flash" link
# ---------------------------------------------------------------------------


def test_pq_row_bytes_16x_below_uint8():
    """The cost model prices a PQ row at pq_m bytes — the code row IS the
    stored unit, not lane-padded — 16x under uint8 at the paper's d=128."""
    from repro.launch.costmodel import vector_row_bytes

    assert vector_row_bytes(128, "pq") == 8
    assert vector_row_bytes(128, "pq", pq_m=16) == 16
    assert vector_row_bytes(128, "uint8") == 16 * vector_row_bytes(128, "pq")
    assert vector_row_bytes(128, "float32") == 64 * vector_row_bytes(
        128, "pq")


def test_pq_store_rows_shrink_and_read_fewer_bytes(backend_zoo):
    """The pq store's vector table holds pq_m-byte uint8 rows (16x under
    the lane-padded uint8 rows at the zoo's d=64), plus a separate
    float32 `rerank_vectors` table; measured cold-cache bytes_read drops
    vs the uint8 store (stage-1 reads only code rows and graph rows — PQ
    needs no sqnorms)."""
    svc_pq = backend_zoo.service("pq_csd", "l2")
    svc_u8 = backend_zoo.service("uint8_csd", "l2")

    t_pq = svc_pq.backend.reader.blockfile.tables["vectors"]
    t_u8 = svc_u8.backend.reader.blockfile.tables["vectors"]
    assert t_pq["dtype"] == "uint8" and t_pq["row_bytes"] == 8
    assert t_u8["row_bytes"] == 16 * t_pq["row_bytes"]
    t_rr = svc_pq.backend.reader.blockfile.tables["rerank_vectors"]
    assert t_rr["dtype"] == "float32"

    from repro.api import SearchService
    from repro.store.csd import CSDBackend
    from repro.store.layout import open_store

    def cold_bytes(svc):
        reader = open_store(svc.backend.reader.path, svc.spec.cache_bytes,
                            prefetch=False)
        try:
            cold = SearchService(svc.spec, CSDBackend(svc.spec, reader))
            resp = cold.search(SearchRequest(queries=backend_zoo.queries(),
                                             k=K, ef=EF, with_stats=True))
            return float(resp.stats.bytes_read)
        finally:
            reader.close()

    b_u8, b_pq = cold_bytes(svc_u8), cold_bytes(svc_pq)
    assert b_pq < b_u8, f"pq read MORE than uint8: {b_pq} vs {b_u8}"
    # at this scale neighbor-table traffic dominates what's left, so the
    # end-to-end ratio sits well under the 16x row ratio — but the vector
    # rows really shrinking (and the sqnorm reads disappearing) must show
    assert b_u8 / b_pq >= 1.5, (
        f"pq store should cut storage bytes (rows 16x smaller, no sqnorm "
        f"reads) — measured {b_u8 / b_pq:.2f}x ({int(b_u8)} vs {int(b_pq)})")
