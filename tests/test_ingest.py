"""repro.ingest: mutable segmented index (streaming insert/delete/compact).

Pins the subsystem's acceptance criteria:
  * interleaved insert/delete recall floor vs a from-scratch rebuild of
    the surviving vectors (pinned seed);
  * deleted ids never surface — merge path AND rerank path;
  * memtable-seal parity (a sealed segment answers like the memtable did);
  * compact() on the csd backend is bit-identical to an in-memory
    partitioned build over the same merged rows;
  * save/load round-trips a half-compacted index (segments + tombstones +
    memtable, manifest v2);
  * csd streaming ingest keeps resident store memory inside the re-split
    cache_bytes budget;
  * serve-layer writes interleave with batched reads snapshot-consistently.
"""

import dataclasses

import numpy as np
import pytest

from repro.api import (IndexSpec, MutableSearchService, SearchRequest,
                       SearchService)
from repro.core.hnsw_graph import GraphBuilder, HNSWConfig, build_hnsw
from repro.data import clustered_vectors

CFG = HNSWConfig(M=8, ef_construction=60, seed=0)
K, EF = 10, 40
# pinned-seed floors: observed mutable recall ~0.97+ on this workload; a
# broken merge/tombstone path drops it far below
RECALL_FLOOR = 0.90
MAX_DROP_VS_REBUILD = 0.05


@pytest.fixture(scope="module")
def stream_data():
    n, d = 1400, 32
    vecs = clustered_vectors(n, d, k=14, seed=0)
    rng = np.random.default_rng(1)
    queries = (vecs[rng.integers(0, n, 12)]
               + rng.normal(scale=1.0, size=(12, d))).astype(np.float32)
    return {"vectors": vecs, "queries": queries}


def _recall(ids, gt, k=K):
    return float(np.mean(
        [len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))]))


def _gt_of(vectors, gids, queries, k=K):
    d2 = (np.einsum("nd,nd->n", vectors, vectors)[None]
          - 2 * queries @ vectors.T
          + np.einsum("qd,qd->q", queries, queries)[:, None])
    return gids[np.argsort(d2, axis=1, kind="stable")[:, :k]]


def _mutable(backend, tmp_path, seal_threshold=300, num_partitions=2,
             **spec_kw):
    kw = dict(backend=backend, num_partitions=num_partitions, hnsw=CFG)
    if backend == "csd":
        kw.update(storage_path=str(tmp_path / "store"), block_size=512,
                  cache_bytes=16384, prefetch=False)
    kw.update(spec_kw)
    return MutableSearchService(IndexSpec(**kw),
                                seal_threshold=seal_threshold)


def _interleaved_workload(svc, vecs):
    """Pinned insert/delete interleaving; returns surviving (gids, mask)."""
    n = len(vecs)
    gids = svc.insert(vecs[: n // 2])
    svc.delete(gids[::5][:60])                     # sealed + memtable rows
    gids2 = svc.insert(vecs[n // 2:])
    svc.delete(gids2[1::7][:40])
    deleted = np.concatenate([gids[::5][:60], gids2[1::7][:40]])
    mask = ~np.isin(np.arange(n), deleted)
    return np.arange(n)[mask], deleted, mask


@pytest.mark.parametrize("backend", ["exact", "partitioned", "csd"])
def test_interleaved_recall_floor_vs_rebuild(backend, stream_data, tmp_path):
    vecs, q = stream_data["vectors"], stream_data["queries"]
    svc = _mutable(backend, tmp_path)
    surv_gids, deleted, mask = _interleaved_workload(svc, vecs)
    gt = _gt_of(vecs[mask], surv_gids, q)

    ids = np.asarray(svc.search(SearchRequest(queries=q, k=K, ef=EF)).ids)
    r_mut = _recall(ids, gt)

    rebuild = SearchService.build(vecs[mask], dataclasses.replace(
        svc.spec, backend="partitioned" if backend == "csd" else backend,
        storage_path=None))
    rb = np.asarray(rebuild.search(SearchRequest(queries=q, k=K, ef=EF)).ids)
    r_reb = _recall(np.where(rb >= 0, surv_gids[np.maximum(rb, 0)], -1), gt)

    assert r_mut >= RECALL_FLOOR, f"{backend}: mutable recall {r_mut:.3f}"
    assert r_mut >= r_reb - MAX_DROP_VS_REBUILD, (
        f"{backend}: mutable {r_mut:.3f} vs rebuild {r_reb:.3f}")
    # deleted ids never surface
    assert not np.isin(ids, deleted).any()
    svc.close()


@pytest.mark.parametrize("backend", ["partitioned", "csd"])
def test_deletes_never_surface_including_rerank(backend, stream_data,
                                               tmp_path):
    vecs, q = stream_data["vectors"], stream_data["queries"]
    svc = _mutable(backend, tmp_path, keep_vectors=backend != "csd")
    gids = svc.insert(vecs)
    # delete the TRUE nearest neighbors so filtering is actually load-bearing
    gt = _gt_of(vecs, np.arange(len(vecs)), q, k=5)
    dele = np.unique(gt.ravel())
    svc.delete(dele)
    for rerank in (False, True):
        resp = svc.search(SearchRequest(queries=q, k=K, ef=EF,
                                        rerank=rerank))
        ids = np.asarray(resp.ids)
        assert not np.isin(ids, dele).any(), f"rerank={rerank}"
        assert (ids[:, 0] >= 0).all()
    # ... and still not after compaction reclaims them
    svc.compact()
    ids = np.asarray(svc.search(SearchRequest(queries=q, k=K, ef=EF)).ids)
    assert not np.isin(ids, dele).any()
    assert svc.size == len(vecs) - len(dele)
    svc.close()


def test_memtable_seal_parity_exact_backend(stream_data, tmp_path):
    """Exact backend: sealing is a pure representation change — the sealed
    segment answers bit-identically to the pre-seal memtable scan (same
    blocked-scan kernel, same CHUNK padding)."""
    vecs, q = stream_data["vectors"][:200], stream_data["queries"]
    svc = _mutable("exact", tmp_path, seal_threshold=1000)
    svc.insert(vecs)
    req = SearchRequest(queries=q, k=K, ef=EF)
    pre = svc.search(req)
    assert svc.num_segments == 0          # still all-memtable
    svc.flush()
    assert svc.num_segments == 1
    post = svc.search(req)
    np.testing.assert_array_equal(np.asarray(pre.ids), np.asarray(post.ids))
    np.testing.assert_allclose(np.asarray(pre.dists),
                               np.asarray(post.dists), rtol=1e-6)


def test_memtable_seal_parity_graph_backend(stream_data, tmp_path):
    """Graph backend: the sealed segment (incrementally-built HNSW via the
    factored insert_point) must find what the exact pre-seal scan found
    for the surviving ids — near-exact at this scale."""
    vecs, q = stream_data["vectors"][:250], stream_data["queries"]
    svc = _mutable("partitioned", tmp_path, seal_threshold=1000)
    gids = svc.insert(vecs)
    svc.delete(gids[3::11])
    req = SearchRequest(queries=q, k=K, ef=64)
    pre = np.asarray(svc.search(req).ids)
    svc.flush()
    post = np.asarray(svc.search(req).ids)
    assert not np.isin(post, gids[3::11]).any()
    overlap = np.mean([len(set(pre[b]) & set(post[b])) / K
                       for b in range(len(q))])
    assert overlap >= 0.95, f"seal changed answers: overlap {overlap:.3f}"


def test_insert_point_factoring_matches_batch_build():
    """build_hnsw == GraphBuilder + insert_point, bit for bit (the levels
    stream, upper-row assignment, and link state all line up)."""
    vecs = clustered_vectors(300, 16, k=6, seed=2)
    g_batch = build_hnsw(vecs, CFG)
    b = GraphBuilder(16, CFG)
    for row in vecs:
        b.insert_point(row)
    g_inc = b.graph()
    np.testing.assert_array_equal(g_batch.levels, g_inc.levels)
    np.testing.assert_array_equal(g_batch.l0_nbrs, g_inc.l0_nbrs)
    np.testing.assert_array_equal(g_batch.up_nbrs, g_inc.up_nbrs)
    np.testing.assert_array_equal(g_batch.up_ptr, g_inc.up_ptr)
    assert (g_batch.entry, g_batch.max_level) == (g_inc.entry, g_inc.max_level)


def test_compact_csd_bit_identical_to_inmemory_partitioned(stream_data,
                                                           tmp_path):
    """Acceptance: compact() on csd == in-memory partitioned over the same
    merged segment — bit-identical ids and distances."""
    vecs, q = stream_data["vectors"], stream_data["queries"]
    svc = _mutable("csd", tmp_path)
    surv_gids, deleted, mask = _interleaved_workload(svc, vecs)
    svc.compact()
    assert svc.num_segments == 1
    resp = svc.search(SearchRequest(queries=q, k=K, ef=EF))

    ref = SearchService.build(vecs[mask], IndexSpec(
        backend="partitioned", num_partitions=2, hnsw=CFG))
    rr = ref.search(SearchRequest(queries=q, k=K, ef=EF))
    ref_ids = np.asarray(rr.ids)
    ref_gids = np.where(ref_ids >= 0, surv_gids[np.maximum(ref_ids, 0)], -1)
    np.testing.assert_array_equal(np.asarray(resp.ids), ref_gids)
    np.testing.assert_array_equal(np.asarray(resp.dists),
                                  np.asarray(rr.dists))
    svc.close()


@pytest.mark.parametrize("backend", ["partitioned", "csd"])
def test_save_load_roundtrips_half_compacted_index(backend, stream_data,
                                                   tmp_path):
    """Manifest v2: segments + tombstones + un-sealed memtable all
    round-trip; the reloaded index answers bit-identically."""
    vecs, q = stream_data["vectors"], stream_data["queries"]
    svc = _mutable(backend, tmp_path)
    surv_gids, deleted, mask = _interleaved_workload(svc, vecs)
    assert svc.num_segments > 1           # genuinely half-compacted
    path = str(tmp_path / "saved")
    svc.save(path)
    svc2 = MutableSearchService.load(path)
    assert svc2.num_segments == svc.num_segments
    assert svc2.size == svc.size
    req = SearchRequest(queries=q, k=K, ef=EF)
    a, b = svc.search(req), svc2.search(req)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    assert not np.isin(np.asarray(b.ids), deleted).any()
    # the reloaded index keeps ingesting: ids continue past the old stream
    new = svc2.insert(vecs[:3])
    assert new.min() >= len(vecs)
    # v2 manifests are refused by the immutable loader, with a pointer
    with pytest.raises(ValueError, match="MutableSearchService"):
        SearchService.load(path)
    svc.close()
    svc2.close()


def test_csd_streaming_ingest_bounded_memory(stream_data, tmp_path):
    """Acceptance: peak resident store memory during csd streaming ingest
    stays inside the (re-split) cache_bytes budget + the memtable buffer,
    no matter how many segments accumulate."""
    vecs, q = stream_data["vectors"], stream_data["queries"]
    spec = IndexSpec(backend="csd", num_partitions=1, hnsw=CFG,
                     storage_path=str(tmp_path / "store"), block_size=512,
                     cache_bytes=8192, prefetch=False)
    svc = MutableSearchService(spec, seal_threshold=150)
    mem_peak = 0
    for lo in range(0, len(vecs), 100):
        svc.insert(vecs[lo: lo + 100])
        svc.search(SearchRequest(queries=q[:4], k=K, ef=EF,
                                 with_stats=True))
        mem_peak = max(mem_peak, svc.resident_bytes()
                       - svc.storage_resident_bytes())
    assert svc.num_segments >= 8
    cache_bound = max(spec.cache_bytes,
                      svc.num_segments * spec.block_size)
    assert svc.peak_storage_resident_bytes <= cache_bound, (
        f"cache residency {svc.peak_storage_resident_bytes} exceeds "
        f"{cache_bound}")
    assert svc.peak_resident_bytes <= cache_bound + mem_peak
    svc.close()


def test_per_segment_stats_reported(stream_data, tmp_path):
    vecs, q = stream_data["vectors"], stream_data["queries"]
    svc = _mutable("csd", tmp_path, seal_threshold=400)
    svc.insert(vecs)
    resp = svc.search(SearchRequest(queries=q, k=K, ef=EF, with_stats=True))
    st = resp.stats
    names = [row["segment"] for row in st.segments]
    assert len(names) == svc.num_segments + 1      # + memtable
    assert names[-1] == "memtable"
    assert st.block_reads and st.block_reads == sum(
        row.get("block_reads", 0) for row in st.segments)
    assert st.dist_calcs is not None and (np.asarray(st.dist_calcs) > 0).all()
    svc.close()


def test_store_segment_manifest_is_crash_safe(tmp_path):
    """segments.json only ever names committed stores; replace is atomic
    and reclaims the dead directories."""
    import os

    from repro.store.blockfile import StoreFormatError
    from repro.store.segments import (append_segment, list_segments,
                                      replace_segments, segment_dir)

    root = str(tmp_path / "segstore")
    os.makedirs(segment_dir(root, "seg_a"))       # no commit marker
    with pytest.raises(StoreFormatError, match="commit marker"):
        append_segment(root, "seg_a")
    assert list_segments(root) == []
    for name in ("seg_a", "seg_b"):
        os.makedirs(segment_dir(root, name), exist_ok=True)
        with open(os.path.join(segment_dir(root, name), "_COMMITTED"),
                  "w") as f:
            f.write("ok")
    append_segment(root, "seg_a")
    append_segment(root, "seg_b")
    assert list_segments(root) == ["seg_a", "seg_b"]
    with pytest.raises(ValueError, match="already published"):
        append_segment(root, "seg_a")
    os.makedirs(segment_dir(root, "seg_c"))
    with open(os.path.join(segment_dir(root, "seg_c"), "_COMMITTED"),
              "w") as f:
        f.write("ok")
    replace_segments(root, ["seg_a", "seg_b"], ["seg_c"])
    assert list_segments(root) == ["seg_c"]
    assert not os.path.exists(segment_dir(root, "seg_a"))


def test_serve_interleaves_writes_with_batched_reads(stream_data, tmp_path):
    """repro.serve threading: mutations through SearchServer are visible
    to every batch dispatched after they return (snapshot consistency),
    and deleted ids never appear in post-delete batches."""
    from repro.serve import SearchServer

    vecs, q = stream_data["vectors"], stream_data["queries"]
    svc = _mutable("partitioned", tmp_path, seal_threshold=200)
    with SearchServer(svc, replicas=2, max_batch=8, max_wait_ms=1.0) as srv:
        gids = srv.insert(vecs[:800])
        futs = srv.submit_many(q, k=K, ef=EF)
        res_a = [f.result(timeout=120) for f in futs]
        assert all((r.ids >= 0).all() for r in res_a)
        gt = _gt_of(vecs[:800], np.arange(800), q, k=3)
        dele = np.unique(gt.ravel())
        assert srv.delete(dele) == len(dele)
        srv.insert(vecs[800:])
        futs = srv.submit_many(q, k=K, ef=EF)
        for f in futs:
            assert not np.isin(f.result(timeout=120).ids, dele).any()
        srv.compact_index()
        assert svc.num_segments == 1
        futs = srv.submit_many(q, k=K, ef=EF)
        for f in futs:
            res = f.result(timeout=120)
            assert (res.ids >= 0).all()
            assert not np.isin(res.ids, dele).any()
    svc.close()


def test_immutable_service_rejects_mutations(stream_data, tmp_path):
    from repro.serve import SearchServer

    svc = SearchService.build(stream_data["vectors"][:256],
                              IndexSpec(backend="exact"))
    with SearchServer(svc, replicas=1) as srv:
        with pytest.raises(TypeError, match="immutable"):
            srv.insert(stream_data["vectors"][:1])


def test_mutable_spec_validation(tmp_path):
    with pytest.raises(ValueError, match="distributed"):
        MutableSearchService(IndexSpec(backend="distributed"))
    with pytest.raises(ValueError, match="float32-only"):
        MutableSearchService(IndexSpec(backend="partitioned", dtype="uint8",
                                       qscale=1.0, qzero=0))
    with pytest.raises(ValueError, match="graph-safe"):
        MutableSearchService(IndexSpec(backend="partitioned", metric="ip"))
    with pytest.raises(ValueError, match="storage_path"):
        MutableSearchService(IndexSpec(backend="csd"))
    # ip is fine on the exact backend
    svc = MutableSearchService(IndexSpec(backend="exact", metric="ip"))
    svc.insert(np.eye(4, dtype=np.float32))
    ids = np.asarray(svc.search(SearchRequest(
        queries=np.eye(4, dtype=np.float32)[:1], k=1)).ids)
    assert ids[0, 0] == 0
