"""Fixed-shape search kernel: JAX == numpy oracle, recall vs exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hnsw_graph as hg
from repro.core.bruteforce import bruteforce_topk
from repro.core.ref_search import ref_batch_search
from repro.core.search import SearchParams, batch_search


@pytest.fixture(scope="module")
def device_db(built_graph):
    g, _ = built_graph
    db_np = hg.restructure(g)
    return db_np, jax.tree.map(jnp.asarray, db_np)


def test_jax_matches_numpy_oracle(device_db, small_dataset):
    db_np, db = device_db
    p = SearchParams(ef=40, k=10)
    ids, ds, stats = batch_search(db, jnp.asarray(small_dataset["queries"]), p)
    rids, rds, rhops, rcalcs = ref_batch_search(
        db_np, small_dataset["queries"], p)
    np.testing.assert_array_equal(np.asarray(ids), rids)
    # distances at SIFT magnitudes (~1e5) lose ~1 ulp*|x|^2 to cancellation
    # in ||x||^2 - 2xq + ||q||^2; ids and hop counts must still be exact.
    np.testing.assert_allclose(np.asarray(ds), rds, rtol=1e-3, atol=2.0)
    np.testing.assert_array_equal(np.asarray(stats.hops), rhops)


@pytest.mark.parametrize("ef", [10, 40])
def test_recall_vs_bruteforce(device_db, small_dataset, ef):
    """ef=40/K=10 is the paper's SIFT1B operating point (recall 0.94);
    on a 2k clustered set the monolithic graph should do at least 0.9."""
    _, db = device_db
    k = small_dataset["k"]
    p = SearchParams(ef=ef, k=k)
    ids, _, _ = batch_search(db, jnp.asarray(small_dataset["queries"]), p)
    ids = np.asarray(ids)
    gt = small_dataset["gt"]
    recall = np.mean([
        len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))])
    floor = 0.9 if ef >= 40 else 0.6
    assert recall >= floor, f"recall@{k} (ef={ef}) = {recall:.3f}"


def test_search_visits_tiny_fraction(device_db, small_dataset):
    """Fig. 9: HNSW reads ~0.03% of the vectors a brute-force scan reads.
    At n=2000 the fraction is larger, but must still be well below 100%."""
    _, db = device_db
    p = SearchParams(ef=40, k=10)
    _, _, stats = batch_search(db, jnp.asarray(small_dataset["queries"]), p)
    n = small_dataset["vectors"].shape[0]
    frac = float(np.mean(np.asarray(stats.dist_calcs))) / n
    assert frac < 0.6, f"graph search visited {frac:.1%} of the dataset"


def test_bruteforce_is_exact(small_dataset):
    vecs = small_dataset["vectors"]
    n, d = vecs.shape
    n_pad = ((n + 511) // 512) * 512
    vp = np.zeros((n_pad, d), np.float32)
    vp[:n] = vecs
    sq = np.full(n_pad, np.inf, np.float32)
    sq[:n] = np.einsum("nd,nd->n", vecs, vecs)
    ids, ds = bruteforce_topk(
        jnp.asarray(vp), jnp.asarray(sq), jnp.asarray(small_dataset["queries"]),
        k=small_dataset["k"], chunk=512)
    np.testing.assert_array_equal(np.asarray(ids), small_dataset["gt"])
    assert np.all(np.diff(np.asarray(ds), axis=1) >= -1e-6), "unsorted output"


def test_empty_slots_are_minus_one(built_graph, small_dataset):
    """k > points reachable -> padded with -1 / inf."""
    g, cfg = built_graph
    db = jax.tree.map(jnp.asarray, hg.restructure(g))
    p = SearchParams(ef=4, k=4)
    ids, ds, _ = batch_search(db, jnp.asarray(small_dataset["queries"][:2]), p)
    assert np.asarray(ids).shape == (2, 4)
