"""Two-stage partitioned search (paper §4.1): no accuracy loss vs exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hnsw_graph as hg
from repro.core.engine import ANNEngine
from repro.core.partitioned import build_partitioned_db, merge_topk, search_partitioned
from repro.core.search import SearchParams


@pytest.fixture(scope="module")
def engine4(small_dataset):
    return ANNEngine.build(
        small_dataset["vectors"], num_partitions=4,
        cfg=hg.HNSWConfig(M=12, ef_construction=80), keep_vectors=True)


def _recall(ids, gt, k):
    return np.mean([len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))])


def test_partitioned_recall_matches_paper_claim(engine4, small_dataset):
    """Paper: partitioned two-stage search shows 'no accuracy loss'
    (recall 0.94 at ef=40/K=10 on SIFT1B)."""
    ids, _ = engine4.search(small_dataset["queries"], k=10, ef=40)
    r = _recall(np.asarray(ids), small_dataset["gt"], 10)
    assert r >= 0.9, f"partitioned recall {r:.3f}"


def test_partition_ids_are_global(engine4, small_dataset):
    ids, _ = engine4.search(small_dataset["queries"], k=10, ef=40)
    ids = np.asarray(ids)
    n = small_dataset["vectors"].shape[0]
    valid = ids[ids >= 0]
    assert valid.max() < n
    # results must come from more than one partition's id range
    assert (valid < n // 4).any() and (valid >= 3 * n // 4).any()


# rerank-preserves-stage-2 parity moved to the shared cross-backend matrix
# (tests/test_parity_matrix.py::test_rerank_preserves_topk_set)


def test_merge_topk_equals_global_sort():
    rng = np.random.default_rng(0)
    ds = rng.uniform(size=(3, 4, 8)).astype(np.float32)   # [B, P, K]
    ids = rng.integers(0, 10_000, size=(3, 4, 8)).astype(np.int32)
    mi, md = merge_topk(jnp.asarray(ids), jnp.asarray(ds), k=5)
    flat_d = ds.reshape(3, -1)
    flat_i = ids.reshape(3, -1)
    order = np.argsort(flat_d, axis=1, kind="stable")[:, :5]
    np.testing.assert_allclose(
        np.asarray(md), np.take_along_axis(flat_d, order, 1), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(mi), np.take_along_axis(flat_i, order, 1))


def test_partitions_have_uniform_shapes(small_dataset):
    pdb = build_partitioned_db(
        small_dataset["vectors"][:1003], 3, hg.HNSWConfig(M=8, ef_construction=40))
    for leaf in jax.tree.leaves(pdb.db):
        assert leaf.shape[0] == 3


def test_engine_bruteforce_agrees_with_gt(engine4, small_dataset):
    ids, _ = engine4.bruteforce(small_dataset["queries"], k=10)
    r = _recall(np.asarray(ids), small_dataset["gt"], 10)
    assert r == 1.0
