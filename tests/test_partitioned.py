"""Two-stage partitioned search (paper §4.1): no accuracy loss vs exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core import hnsw_graph as hg
from repro.core.partitioned import build_partitioned_db, merge_topk
from repro.core.bruteforce import bruteforce_topk


@pytest.fixture(scope="module")
def svc4(small_dataset):
    return SearchService.build(
        small_dataset["vectors"],
        IndexSpec(backend="partitioned", num_partitions=4,
                  hnsw=hg.HNSWConfig(M=12, ef_construction=80),
                  keep_vectors=True))


def _recall(ids, gt, k):
    return np.mean([len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))])


def _search_ids(svc, queries, k=10, ef=40):
    return np.asarray(svc.search(SearchRequest(queries=queries, k=k,
                                               ef=ef)).ids)


def test_partitioned_recall_matches_paper_claim(svc4, small_dataset):
    """Paper: partitioned two-stage search shows 'no accuracy loss'
    (recall 0.94 at ef=40/K=10 on SIFT1B)."""
    ids = _search_ids(svc4, small_dataset["queries"])
    r = _recall(ids, small_dataset["gt"], 10)
    assert r >= 0.9, f"partitioned recall {r:.3f}"


def test_partition_ids_are_global(svc4, small_dataset):
    ids = _search_ids(svc4, small_dataset["queries"])
    n = small_dataset["vectors"].shape[0]
    valid = ids[ids >= 0]
    assert valid.max() < n
    # results must come from more than one partition's id range
    assert (valid < n // 4).any() and (valid >= 3 * n // 4).any()


# rerank-preserves-stage-2 parity moved to the shared cross-backend matrix
# (tests/test_parity_matrix.py::test_rerank_preserves_topk_set)


def test_merge_topk_equals_global_sort():
    rng = np.random.default_rng(0)
    ds = rng.uniform(size=(3, 4, 8)).astype(np.float32)   # [B, P, K]
    ids = rng.integers(0, 10_000, size=(3, 4, 8)).astype(np.int32)
    mi, md = merge_topk(jnp.asarray(ids), jnp.asarray(ds), k=5)
    flat_d = ds.reshape(3, -1)
    flat_i = ids.reshape(3, -1)
    order = np.argsort(flat_d, axis=1, kind="stable")[:, :5]
    np.testing.assert_allclose(
        np.asarray(md), np.take_along_axis(flat_d, order, 1), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(mi), np.take_along_axis(flat_i, order, 1))


def test_partitions_have_uniform_shapes(small_dataset):
    pdb = build_partitioned_db(
        small_dataset["vectors"][:1003], 3, hg.HNSWConfig(M=8, ef_construction=40))
    for leaf in jax.tree.leaves(pdb.db):
        assert leaf.shape[0] == 3


def test_bruteforce_over_restructured_db_agrees_with_gt(svc4, small_dataset):
    """Exact scan over the restructured (partition-stacked, padded) tables
    still finds the true neighbors — the Fig. 9 baseline on the same DB."""
    db = svc4.backend.pdb.db
    P, Np, Dp = db.vectors.shape
    vecs = db.vectors.reshape(P * Np, Dp)
    sq = db.sqnorms.reshape(P * Np)
    queries = jnp.asarray(small_dataset["queries"])
    queries = jnp.pad(queries, ((0, 0), (0, Dp - queries.shape[-1])))
    ids, _ = bruteforce_topk(vecs, sq, queries, k=10, chunk=Np)
    gids = db.gids.reshape(P * Np)
    ids = np.asarray(jnp.where(ids >= 0, gids[jnp.maximum(ids, 0)], -1))
    r = _recall(ids, small_dataset["gt"], 10)
    assert r == 1.0
