"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.core.search import merge_sorted, visited_test_and_set
from repro.optim.compression import compress_grads, decompress_grads

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


# no subnormals: XLA CPU flushes them to zero (FTZ), so tie semantics vs
# numpy differ below the normal range — not an algorithm property.
floats = st.floats(min_value=-1e6, max_value=1e6, width=32,
                   allow_subnormal=False)


@given(st.lists(floats, min_size=1, max_size=24),
       st.lists(floats, min_size=1, max_size=24))
def test_merge_sorted_is_a_sorted_merge(a, b):
    ad = np.sort(np.array(a, np.float32))
    bd = np.sort(np.array(b, np.float32))
    ai = np.arange(len(ad), dtype=np.int32)
    bi = 1000 + np.arange(len(bd), dtype=np.int32)
    od, oi = merge_sorted(jnp.asarray(ad), jnp.asarray(ai),
                          jnp.asarray(bd), jnp.asarray(bi))
    od, oi = np.asarray(od), np.asarray(oi)
    # multiset of values preserved and sorted
    np.testing.assert_allclose(np.sort(np.concatenate([ad, bd])), od)
    assert np.all(np.diff(od) >= 0)
    # ids form a permutation of the inputs
    assert sorted(oi.tolist()) == sorted(ai.tolist() + bi.tolist())


@given(st.lists(floats, min_size=1, max_size=16),
       st.lists(floats, min_size=1, max_size=16))
def test_merge_sorted_tie_break_prefers_existing(a, b):
    """Existing (a) entries must come first among equal distances —
    matches the numpy oracle's stable concat sort."""
    ad = np.sort(np.array(a, np.float32))
    bd = np.sort(np.array(b, np.float32))
    ai = np.zeros(len(ad), np.int32)          # a marked 0
    bi = np.ones(len(bd), np.int32)           # b marked 1
    od, oi = merge_sorted(jnp.asarray(ad), jnp.asarray(ai),
                          jnp.asarray(bd), jnp.asarray(bi))
    d = np.concatenate([ad, bd])
    marks = np.concatenate([np.zeros(len(ad)), np.ones(len(bd))])
    order = np.argsort(d, kind="stable")
    np.testing.assert_array_equal(np.asarray(oi), marks[order].astype(np.int32))


@given(st.lists(st.integers(min_value=0, max_value=255), min_size=1,
                max_size=64, unique=True))
def test_visited_bitmap_test_and_set(ids):
    ids = np.array(ids, np.int32)
    bitmap = jnp.zeros(8, jnp.uint32)
    valid = jnp.ones(len(ids), bool)
    was, bitmap = visited_test_and_set(bitmap, jnp.asarray(ids), valid)
    assert not np.asarray(was).any()
    # second visit: everything flagged
    was2, bitmap2 = visited_test_and_set(bitmap, jnp.asarray(ids), valid)
    assert np.asarray(was2).all()
    np.testing.assert_array_equal(np.asarray(bitmap), np.asarray(bitmap2))


@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=2, max_value=12))
def test_topk_merge_associative(p, k):
    """Stage-2 invariant: top-k of concat == top-k of per-partition top-ks
    (what makes the distributed tree-merge correct)."""
    rng = np.random.default_rng(p * 100 + k)
    d = rng.uniform(size=(p, 50)).astype(np.float32)
    gids = np.arange(p * 50).reshape(p, 50)
    # per-partition top-k
    part = np.sort(d, axis=1)[:, :k]
    part_ids = np.take_along_axis(gids, np.argsort(d, axis=1, kind="stable"), 1)[:, :k]
    merged = np.sort(part.reshape(-1))[:k]
    direct = np.sort(d.reshape(-1))[:k]
    np.testing.assert_allclose(merged, direct)


@given(st.lists(floats, min_size=1, max_size=128))
def test_compression_error_feedback_converges(gs):
    """Error feedback: quantizing the SAME gradient repeatedly with carried
    residual must average out — cumulative mean error -> 0."""
    g = np.array(gs, np.float32)
    err = None
    total = np.zeros_like(g)
    n = 8
    for _ in range(n):
        q, s, err = compress_grads({"g": jnp.asarray(g)},
                                   {"g": err} if err is not None else None)
        total += np.asarray(decompress_grads(q, s)["g"])
        err = jnp.asarray(np.asarray(err["g"]))
        err = {"g": err}
    scale = max(np.abs(g).max(), 1e-3)
    np.testing.assert_allclose(total / n, g, atol=scale / 100 + 1e-6)


@given(st.integers(min_value=1, max_value=300))
def test_vocab_padding_is_multiple_of_256(v):
    from repro.models.transformer import LayerSpec, ModelConfig
    cfg = ModelConfig(name="t", d_model=8, n_heads=1, n_kv_heads=1, head_dim=8,
                      d_ff=8, vocab_size=v, pattern=(LayerSpec(),), num_periods=1)
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= v
