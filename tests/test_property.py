"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.core.search import merge_sorted, visited_test_and_set
from repro.optim.compression import compress_grads, decompress_grads

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


# no subnormals: XLA CPU flushes them to zero (FTZ), so tie semantics vs
# numpy differ below the normal range — not an algorithm property.
floats = st.floats(min_value=-1e6, max_value=1e6, width=32,
                   allow_subnormal=False)


@given(st.lists(floats, min_size=1, max_size=24),
       st.lists(floats, min_size=1, max_size=24))
def test_merge_sorted_is_a_sorted_merge(a, b):
    ad = np.sort(np.array(a, np.float32))
    bd = np.sort(np.array(b, np.float32))
    ai = np.arange(len(ad), dtype=np.int32)
    bi = 1000 + np.arange(len(bd), dtype=np.int32)
    od, oi = merge_sorted(jnp.asarray(ad), jnp.asarray(ai),
                          jnp.asarray(bd), jnp.asarray(bi))
    od, oi = np.asarray(od), np.asarray(oi)
    # multiset of values preserved and sorted
    np.testing.assert_allclose(np.sort(np.concatenate([ad, bd])), od)
    assert np.all(np.diff(od) >= 0)
    # ids form a permutation of the inputs
    assert sorted(oi.tolist()) == sorted(ai.tolist() + bi.tolist())


@given(st.lists(floats, min_size=1, max_size=16),
       st.lists(floats, min_size=1, max_size=16))
def test_merge_sorted_tie_break_prefers_existing(a, b):
    """Existing (a) entries must come first among equal distances —
    matches the numpy oracle's stable concat sort."""
    ad = np.sort(np.array(a, np.float32))
    bd = np.sort(np.array(b, np.float32))
    ai = np.zeros(len(ad), np.int32)          # a marked 0
    bi = np.ones(len(bd), np.int32)           # b marked 1
    od, oi = merge_sorted(jnp.asarray(ad), jnp.asarray(ai),
                          jnp.asarray(bd), jnp.asarray(bi))
    d = np.concatenate([ad, bd])
    marks = np.concatenate([np.zeros(len(ad)), np.ones(len(bd))])
    order = np.argsort(d, kind="stable")
    np.testing.assert_array_equal(np.asarray(oi), marks[order].astype(np.int32))


@given(st.lists(st.integers(min_value=0, max_value=255), min_size=1,
                max_size=64, unique=True))
def test_visited_bitmap_test_and_set(ids):
    ids = np.array(ids, np.int32)
    bitmap = jnp.zeros(8, jnp.uint32)
    valid = jnp.ones(len(ids), bool)
    was, bitmap = visited_test_and_set(bitmap, jnp.asarray(ids), valid)
    assert not np.asarray(was).any()
    # second visit: everything flagged
    was2, bitmap2 = visited_test_and_set(bitmap, jnp.asarray(ids), valid)
    assert np.asarray(was2).all()
    np.testing.assert_array_equal(np.asarray(bitmap), np.asarray(bitmap2))


@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=2, max_value=12))
def test_topk_merge_associative(p, k):
    """Stage-2 invariant: top-k of concat == top-k of per-partition top-ks
    (what makes the distributed tree-merge correct)."""
    rng = np.random.default_rng(p * 100 + k)
    d = rng.uniform(size=(p, 50)).astype(np.float32)
    gids = np.arange(p * 50).reshape(p, 50)
    # per-partition top-k
    part = np.sort(d, axis=1)[:, :k]
    part_ids = np.take_along_axis(gids, np.argsort(d, axis=1, kind="stable"), 1)[:, :k]
    merged = np.sort(part.reshape(-1))[:k]
    direct = np.sort(d.reshape(-1))[:k]
    np.testing.assert_allclose(merged, direct)


@given(st.lists(floats, min_size=1, max_size=128))
def test_compression_error_feedback_converges(gs):
    """Error feedback: quantizing the SAME gradient repeatedly with carried
    residual must average out — cumulative mean error -> 0."""
    g = np.array(gs, np.float32)
    err = None
    total = np.zeros_like(g)
    n = 8
    for _ in range(n):
        q, s, err = compress_grads({"g": jnp.asarray(g)},
                                   {"g": err} if err is not None else None)
        total += np.asarray(decompress_grads(q, s)["g"])
        err = jnp.asarray(np.asarray(err["g"]))
        err = {"g": err}
    scale = max(np.abs(g).max(), 1e-3)
    np.testing.assert_allclose(total / n, g, atol=scale / 100 + 1e-6)


@given(st.integers(min_value=1, max_value=300))
def test_vocab_padding_is_multiple_of_256(v):
    from repro.models.transformer import LayerSpec, ModelConfig
    cfg = ModelConfig(name="t", d_model=8, n_heads=1, n_kv_heads=1, head_dim=8,
                      d_ff=8, vocab_size=v, pattern=(LayerSpec(),), num_periods=1)
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= v


# ---------------------------------------------------------------------------
# dtype="pq": the exact backend == a numpy ADC oracle on the same codebooks
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([1, 2, 4, 8]),
       dsub=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=2**16))
def test_pq_exact_backend_matches_numpy_adc_oracle(m, dsub, seed):
    """For ARBITRARY (m, d = m*dsub, seed): the PQ exact backend's answers
    equal a numpy ADC oracle over the same fitted codebooks — the oracle
    gathers from the same device-built LUT and accumulates one subspace at
    a time in f32, the canonical reduction order, so sorted top-k
    distances match BITWISE; ids are compared only when the oracle's
    distances are strictly unique (ties make the winner selection-order
    dependent)."""
    from repro.api import IndexSpec, SearchRequest, SearchService
    from repro.optim.compression import build_pq_lut

    d = m * dsub
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((96, d)).astype(np.float32)
    q = rng.standard_normal((3, d)).astype(np.float32)
    k = 8
    svc = SearchService.build(
        vecs, IndexSpec(backend="exact", dtype="pq", pq_m=m))
    resp = svc.search(SearchRequest(queries=q, k=k))

    quant = svc.quantizer
    codes = quant.encode(vecs).astype(np.int64)
    lut = np.asarray(build_pq_lut(jnp.asarray(q),
                                  jnp.asarray(quant.codebooks)))
    acc = np.zeros((len(q), len(vecs)), np.float32)
    for mi in range(m):
        acc = acc + lut[:, mi, codes[:, mi]]
    np.testing.assert_array_equal(np.asarray(resp.dists),
                                  np.sort(acc, axis=1)[:, :k])
    ids = np.asarray(resp.ids)
    for b in range(len(q)):
        if np.unique(acc[b]).size == acc[b].size:
            want = np.argsort(acc[b], kind="stable")[:k]
            np.testing.assert_array_equal(ids[b], want)


# ---------------------------------------------------------------------------
# repro.serve: the dynamic batcher is lossless and transparent
# ---------------------------------------------------------------------------

_SERVE_CTX: dict = {}


def _serve_ctx():
    """One tiny exact-backend service shared across examples (module-level
    cache, not a fixture: @given and function fixtures don't mix)."""
    if not _SERVE_CTX:
        from repro.api import IndexSpec, SearchService
        rng = np.random.default_rng(7)
        vecs = rng.normal(size=(256, 16)).astype(np.float32)
        _SERVE_CTX["vecs"] = vecs
        _SERVE_CTX["svc"] = SearchService.build(
            vecs, IndexSpec(backend="exact"))
    return _SERVE_CTX["svc"], _SERVE_CTX["vecs"]


# ---------------------------------------------------------------------------
# repro.ingest: any insert/delete/search interleaving matches a numpy oracle
# ---------------------------------------------------------------------------

_INGEST_POOL: dict = {}


def _ingest_pool():
    """Deterministic vector pool (integer-valued: f32 distances are exact,
    so oracle comparisons cannot hinge on rounding)."""
    if not _INGEST_POOL:
        rng = np.random.default_rng(11)
        _INGEST_POOL["vecs"] = rng.integers(
            -8, 8, size=(256, 8)).astype(np.float32)
    return _INGEST_POOL["vecs"]


@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("insert"),
                      st.integers(min_value=1, max_value=24)),
            st.tuples(st.just("delete"),
                      st.integers(min_value=0, max_value=10_000)),
            st.tuples(st.just("search"),
                      st.integers(min_value=1, max_value=8)),
        ),
        min_size=1, max_size=24),
    seal_threshold=st.integers(min_value=4, max_value=64),
)
def test_mutable_index_matches_numpy_oracle(ops, seal_threshold):
    """Exact-backend memtables under ANY interleaving of insert/delete/
    search equal a naive numpy oracle over the surviving rows: same
    distance multiset, only live ids, deleted ids never surface."""
    from repro.api import IndexSpec, MutableSearchService, SearchRequest

    pool = _ingest_pool()
    svc = MutableSearchService(IndexSpec(backend="exact"),
                               seal_threshold=seal_threshold)
    live: dict[int, np.ndarray] = {}      # gid -> vector (the oracle)
    cursor = 0
    next_gid = 0
    for op, arg in ops:
        if op == "insert":
            rows = pool[cursor % 200: cursor % 200 + arg]
            cursor += arg
            gids = svc.insert(rows)
            assert gids.tolist() == list(range(next_gid,
                                               next_gid + len(rows)))
            next_gid += len(rows)
            live.update(zip(gids.tolist(), rows))
        elif op == "delete":
            assigned = sorted(live)
            victims = ([assigned[arg % len(assigned)]] if assigned else []) \
                + [arg]                    # one live id + an arbitrary one
            svc.delete(np.asarray(victims, np.int64))
            for v in victims:
                live.pop(v, None)
        else:
            k = arg
            q = pool[(cursor + 7) % 240: (cursor + 7) % 240 + 2]
            resp = svc.search(SearchRequest(queries=q, k=k))
            ids = np.asarray(resp.ids)
            dists = np.asarray(resp.dists)
            if not live:
                assert (ids == -1).all()
                continue
            oracle_gids = np.asarray(sorted(live), np.int64)
            oracle_vecs = np.stack([live[g] for g in oracle_gids])
            d2 = (np.einsum("nd,nd->n", oracle_vecs, oracle_vecs)[None]
                  - 2 * q @ oracle_vecs.T
                  + np.einsum("qd,qd->q", q, q)[:, None])
            k_eff = min(k, len(oracle_gids))
            for b in range(len(q)):
                got_i, got_d = ids[b], dists[b]
                assert (got_i[:k_eff] >= 0).all()
                assert (got_i[k_eff:] == -1).all()
                # every returned id is live, and its distance is exact
                for j in range(k_eff):
                    assert int(got_i[j]) in live
                    idx = int(np.searchsorted(oracle_gids, got_i[j]))
                    np.testing.assert_allclose(got_d[j], d2[b, idx],
                                               rtol=0, atol=0)
                # the distance multiset equals the oracle's k smallest
                np.testing.assert_allclose(
                    np.sort(got_d[:k_eff]), np.sort(d2[b])[:k_eff],
                    rtol=0, atol=0)
    svc.close()


# ---------------------------------------------------------------------------
# repro.cluster: ANY shard assignment of rows matches the numpy oracle
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n_rows=st.integers(min_value=8, max_value=120),
    n_shards=st.integers(min_value=1, max_value=5),
    k=st.integers(min_value=1, max_value=12),
    assign_seed=st.integers(min_value=0, max_value=2**16),
)
def test_cluster_random_sharding_matches_numpy_oracle(
        n_rows, n_shards, k, assign_seed):
    """Scatter rows across shards ARBITRARILY (not the contiguous split) —
    the router's merge must still equal a numpy scan over all rows: exact
    distances (integer-valued pool), only real ids, k-smallest multiset.
    """
    from repro.api import IndexSpec, SearchRequest
    from repro.cluster import ClusterRouter, make_shard

    pool = _ingest_pool()
    vecs = pool[:n_rows]
    arng = np.random.default_rng(assign_seed)
    assign = arng.integers(0, n_shards, size=n_rows)
    spec = IndexSpec(backend="exact")
    clients = []
    for s in range(n_shards):
        gids = np.flatnonzero(assign == s).astype(np.int64)
        if gids.size == 0:
            continue                      # hypothesis may empty a shard
        clients.append(make_shard(vecs[gids], spec, name=f"s{s}",
                                  gid_map=gids))
    if not clients:
        return
    router = ClusterRouter(spec, clients)
    try:
        q = pool[200:204, :].astype(np.float32)
        resp = router.search(SearchRequest(queries=q, k=k))
        ids = np.asarray(resp.ids)
        dists = np.asarray(resp.dists)
        d2 = (np.einsum("nd,nd->n", vecs, vecs)[None]
              - 2 * q @ vecs.T + np.einsum("qd,qd->q", q, q)[:, None])
        k_eff = min(k, n_rows)
        for b in range(len(q)):
            assert (ids[b, :k_eff] >= 0).all()
            assert (ids[b, k_eff:] == -1).all()
            # every id is a real row with its exact distance
            for j in range(k_eff):
                np.testing.assert_allclose(
                    dists[b, j], d2[b, int(ids[b, j])], rtol=0, atol=0)
            # the k smallest distances, as a multiset
            np.testing.assert_allclose(np.sort(dists[b, :k_eff]),
                                       np.sort(d2[b])[:k_eff],
                                       rtol=0, atol=0)
    finally:
        router.close()


@settings(max_examples=15, deadline=None)
@given(
    plan=st.lists(
        st.tuples(st.integers(min_value=0, max_value=255),   # query anchor
                  st.integers(min_value=1, max_value=10),    # k
                  st.sampled_from([0.0, 0.0005, 0.002])),    # arrival gap s
        min_size=1, max_size=16),
    max_batch=st.integers(min_value=1, max_value=8),
    max_wait_ms=st.sampled_from([0.5, 2.0, 10.0]),
)
def test_dynamic_batcher_is_lossless_and_matches_direct(
        plan, max_batch, max_wait_ms):
    """Under random arrival schedules, k values, and batch/wait limits, the
    batcher (a) loses no request, (b) duplicates no request, and (c) every
    response is bit-identical to a direct SearchService.search."""
    import time as _time

    from repro.api import SearchRequest
    from repro.serve import SearchServer

    svc, vecs = _serve_ctx()
    with SearchServer(svc, replicas=1, max_batch=max_batch,
                      max_wait_ms=max_wait_ms) as srv:
        submitted = []
        for anchor, k, gap in plan:
            if gap:
                _time.sleep(gap)
            q = vecs[anchor] + np.float32(0.01)
            submitted.append((srv.submit(q, k=k, ef=16), q, k))
        results = [(f.result(timeout=120), q, k) for f, q, k in submitted]
        roll = srv.stats()

    # (a) no request lost: every future resolved
    assert len(results) == len(plan)
    assert roll.completed == len(plan)
    # (b) no request duplicated: the real (pre-padding) batch sizes sum to
    # exactly the number of submissions
    assert sum(s * c for s, c in roll.batch_sizes.items()) == len(plan)
    assert all(s <= max_batch for s in roll.batch_sizes)
    # (c) every response == direct search of that query at its own k:
    # ids bit-identical; distances to a few ulps of ||x||^2 — XLA CPU
    # matmul rounding is batch-shape-dependent, and the cancellation in
    # ||x||^2 - 2 x.q + ||q||^2 scales the absolute error with the squared
    # norms (~16 here), not with the distance itself
    for res, q, k in results:
        direct = svc.search(SearchRequest(queries=q[None], k=k))
        assert res.ids.shape == (k,)
        np.testing.assert_array_equal(res.ids, np.asarray(direct.ids)[0])
        np.testing.assert_allclose(res.dists, np.asarray(direct.dists)[0],
                                   rtol=1e-3, atol=1e-4)
