"""Graph construction + restructured database (paper §4.3)."""

import numpy as np

from repro.core import hnsw_graph as hg


def test_build_produces_connected_layer0(built_graph, small_dataset):
    g, cfg = built_graph
    n = small_dataset["vectors"].shape[0]
    deg = (g.l0_nbrs >= 0).sum(axis=1)
    assert deg.min() >= 1, "isolated point in layer 0"
    assert deg.max() <= cfg.maxM0
    # links are valid ids
    assert g.l0_nbrs.max() < n


def test_levels_geometric(built_graph):
    g, _ = built_graph
    counts = np.bincount(g.levels)
    # each level should be (roughly) a constant factor smaller
    assert counts[0] > counts[1:].sum(), "level sampling is off"
    assert g.max_level >= 1


def test_restructure_alignment_and_padding(built_graph):
    g, cfg = built_graph
    db = hg.restructure(g)
    n_pad, d_pad = db.vectors.shape
    assert n_pad % 32 == 0, "bitmap wants whole 32-bit words"
    assert d_pad % cfg.lane == 0, "raw-data rows must be lane-aligned"
    assert db.l0_nbrs.shape[1] % cfg.nbr_pad == 0
    # padding rows can never win a distance comparison
    assert np.all(np.isinf(db.sqnorms[int(db.n_valid):]))
    assert np.all(db.l0_nbrs[int(db.n_valid):] == -1)


def test_restructure_dedups_rows(built_graph):
    g, cfg = built_graph
    bad = g.l0_nbrs.copy()
    bad[0, 1] = bad[0, 0]  # inject duplicate
    g2 = g._replace(l0_nbrs=bad)
    db = hg.restructure(g2)
    row = db.l0_nbrs[0]
    row = row[row >= 0]
    assert len(np.unique(row)) == len(row)


def test_size_overhead_matches_paper(built_graph):
    """Paper §4.3: restructured DB costs ~4% over the compact layout.

    Our padded SoA trades a little more (padding to TPU tiles, not 64B),
    but must stay within a small constant factor of hnswlib's layout."""
    g, cfg = built_graph
    db = hg.restructure(g)
    orig = hg.original_size_bytes(g)
    new = hg.db_size_bytes(db)["total"]
    overhead = new / orig
    assert 1.0 <= overhead < 1.9, f"restructuring overhead {overhead:.2f}x"


def test_visited_bitmap_size_matches_paper():
    """Paper §5.2.6: 0.62 MB bitmap for 5M points — ours is byte-identical
    (5e6 / 8 bytes)."""
    n = 5_000_000
    n_pad = ((n + 31) // 32) * 32
    assert abs(n_pad / 8 / 1e6 - 0.625) < 1e-2
