"""Distributed engine + dry-run plumbing (subprocess: own device counts)."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=ROOT)


@pytest.mark.slow
def test_graph_and_query_parallelism_match_single_device():
    r = _run([os.path.join(ROOT, "tests", "helpers", "dist_check.py")])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "graph-parallel == single-device" in r.stdout
    assert "query-parallel consistent" in r.stdout


@pytest.mark.slow
def test_dryrun_single_cell_multi_pod():
    """End-to-end dry-run CLI on the smallest cell, multi-pod mesh (512
    fake devices): proves the `pod` axis shards."""
    r = _run(["-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
              "--shape", "decode_32k", "--mesh", "multi"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["mesh"] == "multi"


def test_collective_parser():
    from repro.launch.roofline import collective_bytes
    txt = """
      %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[64]{0} all-reduce-start(%y)
      %cp = (f32[2,2]{1,0}, f32[2,2]{1,0}) collective-permute(%z)
    """
    out = collective_bytes(txt)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 64 * 4 * 2          # 2x ring factor
    assert out["collective-permute"] == 2 * 2 * 4 * 2
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
