"""Distributed ANN correctness worker (run under 8 fake devices).

Asserts:
  * the graph-parallel shard_map search (backend="distributed" through
    repro.api) returns the same results as the single-device partitioned
    engine;
  * query parallelism (dp axis) returns per-query-identical output.
Exit code 0 == pass. Launched by tests/test_distributed.py in a subprocess
so the parent pytest process keeps its 1-device view.
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import jax
import numpy as np

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core import hnsw_graph as hg
from repro.data import clustered_vectors


def main():
    assert len(jax.devices()) == 8, jax.devices()
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    vecs = clustered_vectors(1600, 32, k=16, seed=0)
    rng = np.random.default_rng(1)
    queries = vecs[rng.integers(0, 1600, 8)] + rng.normal(
        scale=1.0, size=(8, 32)).astype(np.float32)
    queries = queries.astype(np.float32)

    cfg = hg.HNSWConfig(M=8, ef_construction=60)
    k, ef = 8, 32

    # single-device reference (partitioned backend, same graph seed)
    ref_svc = SearchService.build(vecs, IndexSpec(
        backend="partitioned", num_partitions=4, hnsw=cfg))
    ref = ref_svc.search(SearchRequest(queries=queries, k=k, ef=ef))
    ref_ids, ref_ds = np.asarray(ref.ids), np.asarray(ref.dists)

    # graph parallelism over the mesh: 4 partitions / 4 `model` devices
    svc = SearchService.build(vecs, IndexSpec(
        backend="distributed", num_partitions=4, hnsw=cfg), mesh=mesh)
    resp = svc.search(SearchRequest(queries=queries, k=k, ef=ef))
    ids, ds = np.asarray(resp.ids), np.asarray(resp.dists)

    for b in range(len(queries)):
        assert set(ids[b]) == set(ref_ids[b]), (b, ids[b], ref_ids[b])
    np.testing.assert_allclose(np.sort(ds, 1), np.sort(ref_ds, 1), rtol=1e-5)
    print("DIST OK: graph-parallel == single-device")

    # query parallelism: batch twice the dp size, same per-query answers
    q2 = np.concatenate([queries, queries], 0)
    ids2 = np.asarray(svc.search(SearchRequest(queries=q2, k=k, ef=ef)).ids)
    for b in range(len(queries)):
        assert set(ids2[b]) == set(ids2[b + len(queries)])
    print("DIST OK: query-parallel consistent")


if __name__ == "__main__":
    main()
