"""Checkpoint store: roundtrip, commit protocol, async, GC."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint)


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "opt": {"step": jnp.int32(7)}}


def test_roundtrip(tmp_path, tree):
    save_checkpoint(str(tmp_path), 7, tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = restore_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_uncommitted_checkpoints_ignored(tmp_path, tree):
    d = save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 5, tree)
    os.remove(os.path.join(str(tmp_path), "step_00000005", "_COMMITTED"))
    assert latest_step(str(tmp_path)) == 3


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path)) is None


def test_async_checkpointer_and_gc(tmp_path, tree):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    steps = sorted(n for n in os.listdir(str(tmp_path)) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert latest_step(str(tmp_path)) == 4


def test_restore_with_resharding_spec(tmp_path, tree):
    """Elastic restore: pass explicit (single-device) shardings."""
    save_checkpoint(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    back = restore_checkpoint(str(tmp_path), 1, tree, shardings=shardings)
    np.testing.assert_array_equal(
        np.asarray(back["params"]["w"]), np.asarray(tree["params"]["w"]))
