"""repro.serve: dynamic batcher, replica dispatch, async/direct parity.

The acceptance bar (ISSUE 3): the async serve path must return bit-identical
ids to a direct `SearchService.search` for EVERY backend — batching,
variable-k packing, bucket padding, and replica dispatch are all pure
plumbing and may not change a single result.
"""

import threading
import time

import numpy as np
import pytest

from repro.api import SearchRequest
from repro.serve import (
    DynamicBatcher,
    ReplicaPool,
    RequestQueue,
    SearchServer,
    ServeClosed,
    bucket_size,
)

K, EF = 10, 40


@pytest.fixture(scope="module")
def svc(backend_zoo):
    return backend_zoo.service("partitioned", "l2")


def _direct_ids(service, queries, k=K, ef=EF):
    return np.asarray(service.search(
        SearchRequest(queries=np.atleast_2d(queries), k=k, ef=ef)).ids)


# ---------------------------------------------------------------------------
# batcher mechanics
# ---------------------------------------------------------------------------


def test_flush_on_max_batch(svc, backend_zoo):
    """max_batch queued requests flush immediately — long before the
    (deliberately huge) max_wait deadline."""
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4,
                      max_wait_ms=60_000.0) as srv:
        futs = [srv.submit(x, k=K, ef=EF) for x in q[:4]]
        res = [f.result(timeout=60) for f in futs]
        st = srv.stats()
    assert st.batch_sizes == {4: 1}
    ids = np.stack([r.ids for r in res])
    np.testing.assert_array_equal(ids, _direct_ids(svc, q[:4]))


def test_flush_on_max_wait(svc, backend_zoo):
    """A partial batch flushes once the head of line has waited max_wait."""
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=64, max_wait_ms=30.0) as srv:
        t0 = time.perf_counter()
        futs = [srv.submit(x, k=K, ef=EF) for x in q[:3]]
        res = [f.result(timeout=60) for f in futs]
        st = srv.stats()
    assert st.batch_sizes == {3: 1}           # one flush, nothing waited out
    assert all(r.queue_ms >= 25.0 for r in res)   # they DID wait ~max_wait
    assert time.perf_counter() - t0 < 30          # ...not the full minute
    ids = np.stack([r.ids for r in res])
    np.testing.assert_array_equal(ids, _direct_ids(svc, q[:3]))


def test_result_to_request_ordering_under_interleaved_arrival(
        svc, backend_zoo):
    """Concurrent submitters with jittered arrival: every future must get
    ITS OWN query's results (scatter routes by future, not position)."""
    q = backend_zoo.queries()
    direct = _direct_ids(svc, q)
    out: dict[int, np.ndarray] = {}
    lock = threading.Lock()

    with SearchServer(svc, replicas=2, max_batch=5, max_wait_ms=5.0) as srv:
        def client(worker: int):
            for i in range(worker, len(q), 4):
                time.sleep(0.001 * (i % 3))
                res = srv.submit(q[i], k=K, ef=EF).result(timeout=120)
                with lock:
                    out[i] = res.ids
        threads = [threading.Thread(target=client, args=(w,))
                   for w in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert sorted(out) == list(range(len(q)))
    for i, ids in out.items():
        np.testing.assert_array_equal(ids, direct[i])


def test_variable_k_requests_pack_into_one_batch(svc, backend_zoo):
    """k is not part of the batch key: mixed-k requests ride one batch
    (packed at k_max) and each gets its own bit-identical k-prefix."""
    q = backend_zoo.queries()
    ks = [3, 10, 7, 1]
    with SearchServer(svc, replicas=1, max_batch=4,
                      max_wait_ms=60_000.0) as srv:
        futs = [srv.submit(q[i], k=k, ef=EF) for i, k in enumerate(ks)]
        res = [f.result(timeout=60) for f in futs]
        st = srv.stats()
    assert st.batch_sizes == {4: 1}           # one packed batch, despite ks
    for i, (r, k) in enumerate(zip(res, ks)):
        assert r.ids.shape == (k,)
        np.testing.assert_array_equal(r.ids, _direct_ids(svc, q[i], k=k)[0])


def test_drain_returns_all_futures(svc, backend_zoo):
    q = backend_zoo.queries()
    srv = SearchServer(svc, replicas=2, max_batch=4, max_wait_ms=1.0)
    try:
        futs = srv.submit_many(np.repeat(q, 3, axis=0), k=K, ef=EF)
        assert srv.drain(timeout=120)
        assert all(f.done() for f in futs)
        assert srv.stats().completed == len(futs)
    finally:
        srv.shutdown()


def test_submit_after_shutdown_raises(svc, backend_zoo):
    srv = SearchServer(svc, replicas=1)
    srv.shutdown()
    with pytest.raises(ServeClosed):
        srv.submit(backend_zoo.queries()[0])
    # the raw queue refuses too (not just the server wrapper)
    queue = RequestQueue()
    queue.close()
    with pytest.raises(ServeClosed):
        queue.put(backend_zoo.queries()[0])


def test_batch_key_separates_incompatible_requests(svc, backend_zoo):
    """Different ef -> different traversal -> must not share a batch."""
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=8, max_wait_ms=5.0) as srv:
        futs = ([srv.submit(q[i], k=K, ef=40) for i in range(3)]
                + [srv.submit(q[i], k=K, ef=24) for i in range(3, 6)])
        res = [f.result(timeout=60) for f in futs]
    np.testing.assert_array_equal(
        np.stack([r.ids for r in res[:3]]), _direct_ids(svc, q[:3], ef=40))
    np.testing.assert_array_equal(
        np.stack([r.ids for r in res[3:]]), _direct_ids(svc, q[3:6], ef=24))


def test_dispatch_failure_lands_on_futures(svc, backend_zoo):
    """A failing backend call must reject the batch's futures, not hang."""
    queue = RequestQueue()

    def boom(_req, n_queries=0):
        raise RuntimeError("replica on fire")

    b = DynamicBatcher(queue, boom, max_batch=2, max_wait_ms=5.0)
    b.start()
    p = queue.put(backend_zoo.queries()[0], k=K, ef=EF)
    with pytest.raises(RuntimeError, match="replica on fire"):
        p.future.result(timeout=30)
    queue.close()
    b.join(timeout=10)
    assert not b.alive


def test_bucket_size_shapes():
    assert [bucket_size(n, 64) for n in (1, 2, 3, 5, 9, 64)] == \
        [1, 2, 4, 8, 16, 64]
    assert bucket_size(33, 48) == 48          # capped at max_batch
    assert bucket_size(50, 48) == 50          # n > max_batch never shrinks


# ---------------------------------------------------------------------------
# latency semantics + stats rollup
# ---------------------------------------------------------------------------


def test_latency_split_and_stats_rollup(svc, backend_zoo):
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=2, max_batch=8, max_wait_ms=2.0) as srv:
        res = [f.result(timeout=120)
               for f in srv.submit_many(q, k=K, ef=EF, with_stats=True)]
        st = srv.stats()
    for r in res:
        assert r.queue_ms >= 0 and r.exec_ms > 0
        assert r.e2e_ms == pytest.approx(r.queue_ms + r.exec_ms, rel=1e-6)
        # per-query stats rows were scattered back per request
        assert np.asarray(r.stats.dist_calcs).shape == ()
        assert int(r.stats.dist_calcs) > 0
    assert st.completed == len(q)
    assert st.qps > 0
    assert sum(s * c for s, c in st.batch_sizes.items()) == len(q)
    assert len(st.replicas) == 2
    # per-replica counters count REAL requests, never bucket-padding rows
    assert sum(r["queries"] for r in st.replicas) == len(q)
    assert "QPS" in st.summary()


def test_replica_pool_balances_and_round_robins(svc):
    """Ties round-robin; depth imbalance routes to the idler replica."""
    pool = ReplicaPool.replicate(svc, 2)
    try:
        picked = []

        def slow(rid, orig):
            # keep each replica visibly busy so in-flight depth, not the
            # race to finish, decides the next placement
            def wrapped(req, n_queries):
                picked.append(rid)
                time.sleep(0.05)
                return orig(req, n_queries)
            return wrapped

        for rid in (0, 1):
            pool.replicas[rid]._search = slow(
                rid, pool.replicas[rid]._search)
        q = np.zeros((2, 64), np.float32)
        futs = [pool.submit(SearchRequest(queries=q, k=K, ef=EF))
                for _ in range(4)]
        for f in futs:
            f.result(timeout=60)
        assert sorted(picked) == [0, 0, 1, 1]   # 2 batches each
    finally:
        pool.close()


def test_csd_replicas_have_independent_caches(backend_zoo):
    """csd replication = one block store, N PageCaches (the paper's four
    SmartSSD DRAM tiers): each replica reports its own block traffic."""
    svc_csd = backend_zoo.service("csd", "l2")
    q = backend_zoo.queries()
    with SearchServer(svc_csd, replicas=2, max_batch=4,
                      max_wait_ms=1.0) as srv:
        for f in srv.submit_many(np.repeat(q, 2, axis=0), k=K, ef=EF):
            f.result(timeout=300)
        st = srv.stats()
    readers = {id(r.service.backend.reader) for r in srv.pool.replicas}
    assert len(readers) == 2                  # distinct StoreReaders
    for r in st.replicas:
        assert r["backend"] == "csd"
        assert r["queries"] > 0               # both replicas actually served
        assert r["block_reads"] > 0
        assert 0.0 <= r["cache_hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# the acceptance bar: async == direct, for every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["exact", "hnsw", "partitioned",
                                     "distributed", "csd"])
def test_async_serve_is_bit_identical_to_direct(backend, backend_zoo):
    service = backend_zoo.service(backend, "l2")
    q = backend_zoo.queries()
    direct = service.search(SearchRequest(queries=q, k=K, ef=EF))
    with SearchServer(service, replicas=2, max_batch=4,
                      max_wait_ms=1.0) as srv:
        res = [f.result(timeout=300)
               for f in srv.submit_many(q, k=K, ef=EF)]
    np.testing.assert_array_equal(np.stack([r.ids for r in res]),
                                  np.asarray(direct.ids))
    # distances to a few ulps of ||x||^2: XLA CPU matmul rounding depends
    # on the batch shape, and the async path packs different batch sizes
    # than `direct` (same tolerance rationale as test_api's rerank check)
    np.testing.assert_allclose(np.stack([r.dists for r in res]),
                               np.asarray(direct.dists),
                               rtol=1e-3, atol=2.0)
